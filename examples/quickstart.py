"""Quickstart: declare an op ONCE, run it everywhere (the paper's core claim).

``define_op`` is the host API: you write (1) a kernel builder in the unified
language and (2) a pure oracle, and the front-end owns backend selection,
shape->defines derivation, the kernel build cache, autotuning and (when
declared) the custom VJP — the OCCA device/kernel/tuning surface as one
declaration.

  PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core import BACKENDS, Spec, Tile, define_op, get_op, registered_ops


# 1. Write the kernel ONCE (OCCA-style: grid of work-groups over tiles).
def axpby_builder(D):
    def body(ctx, x, y, out):
        # ctx.outer_id / ctx.lane_ids are the occaOuterId/occaInnerId analogues
        out[...] = D.alpha * x[...] + D.beta * y[...]

    return Spec(
        "axpby", grid=(D.n // D.bn,),
        inputs=[Tile("x", (D.n,), jnp.float32, block=(D.bn,)),
                Tile("y", (D.n,), jnp.float32, block=(D.bn,))],
        outputs=[Tile("out", (D.n,), jnp.float32, block=(D.bn,))],
        body=body)


# 2. Write the oracle (what the kernel MUST compute, any backend).
def axpby_ref(x, y, *, alpha=2.0, beta=-0.5):
    return alpha * x + beta * y


# 3. Declare the op: shapes -> defines is the only host logic you write.
axpby = define_op(
    "axpby",
    builder=axpby_builder,
    ref=axpby_ref,
    derive_defines=lambda args, params: dict(
        n=args[0].size, bn=min(params["bn"], args[0].size),
        alpha=params["alpha"], beta=params["beta"]),
    defaults=dict(alpha=2.0, beta=-0.5, bn=4096),
    ref_params=("alpha", "beta"),
    sweep=dict(bn=[512, 2048, 4096, 16384]),
)


def main():
    # keep the demo's tune cache out of the user's real ~/.cache (CI runs
    # this script); export REPRO_CACHE_DIR yourself to see cross-process hits
    import os
    import tempfile
    os.environ.setdefault("REPRO_CACHE_DIR", tempfile.mkdtemp(prefix="repro-occa-"))

    rng = np.random.RandomState(0)
    x = rng.randn(1 << 16).astype(np.float32)
    y = rng.randn(1 << 16).astype(np.float32)
    want = axpby_ref(x, y)

    # 4. Same call site for every backend — the backend is a RUN-TIME knob
    #    ("auto" = pallas, interpret off-TPU). Kernel builds are cached.
    for backend in ("auto",) + BACKENDS:     # auto, jnp, loops, pallas
        got = np.asarray(axpby(x, y, backend=backend))
        np.testing.assert_allclose(got, want, rtol=1e-6)
        print(f"{backend:>7s}: OK  (max|err| = {np.abs(got - want).max():.2e})")

    # 5. The declaration registers the op: tooling can enumerate every op
    #    and its oracle (the registry-wide portability test does exactly this).
    import repro.kernels  # noqa: F401 — registers the library op families
    assert get_op("axpby") is axpby
    print("registry:", ", ".join(sorted(registered_ops())))

    # 6. Per-op autotuning: sweep the declared knobs on real args, validate
    #    every candidate against the oracle, persist the winner on disk
    #    (~/.cache/repro-occa) — a warm cache re-times NOTHING.
    best = axpby.tune((x, y), backend="jnp", repeats=1)
    print(f"tuned bn={best['bn']} "
          f"({'cache hit' if best.cached else f'{len(best.trials)} trials'}, "
          f"best {best.best_seconds * 1e6:.0f} us)")

    # 7. Custom-VJP ops: declare vjp=OpVJP(bwd=...) and the op becomes
    #    differentiable with the BACKWARD also built from unified-language
    #    kernels, run on the same backend as the forward. flash_attention is
    #    the full-size example: its bwd is ONE fused dq/dk/dv kernel whose
    #    outputs accumulate at different reduce granularities
    #    (Tile(reduce=...) — dq over k-blocks, dk/dv over q-blocks, one grid).
    import jax
    from repro.kernels.flash_attention import flash_attention

    q = rng.randn(1, 2, 64, 32).astype(np.float32)
    k = rng.randn(1, 2, 64, 32).astype(np.float32)
    v = rng.randn(1, 2, 64, 32).astype(np.float32)
    for backend in BACKENDS:
        dq = jax.grad(lambda q_: (flash_attention(
            q_, k, v, block_q=32, block_kv=32, backend=backend) ** 2).sum())(q)
        print(f"{backend:>7s}: flash_attention grad OK "
              f"(|dq| = {float(jnp.abs(dq).mean()):.3f})")

    # 8. DYNAMIC input tiles: run-time data the kernel reads WITHOUT
    #    recompiling — the decode-attention pattern. Two flavors:
    #      whole-array  (block=None) — visible to every grid cell; use for
    #                   scalars like flash_decode's (1,1) kv_len, which
    #                   drives a ctx.cell_when predicate so cache blocks past
    #                   the valid length are skipped at RUN time
    #      blocked      — streamed per grid cell like any data tile; use for
    #                   per-slot state like flash_decode's (1,S) slot_pos
    #                   map: a rolling-window cache stores ROTATED slots
    #                   (slot = pos % W), and the mask reads each slot's
    #                   absolute position instead of assuming order
    #    One compiled kernel then serves every step of a growing — even
    #    wrapping — cache. cell_when can still skip whole blocks whenever
    #    the predicate is computable from the dynamic scalars (here: while
    #    kv_len <= S the cache hasn't rotated, so past-the-query blocks
    #    never issue MXU work).
    from repro.kernels.flash_attention import (decode_attention, decode_ref,
                                               rolling_slot_pos)

    W = 16                                   # rolling cache of W slots
    t = 25                                   # decoded PAST the wrap (t > W)
    kc = rng.randn(1, 2, W, 32).astype(np.float32)
    vc = rng.randn(1, 2, W, 32).astype(np.float32)
    q1 = rng.randn(1, 2, 1, 32).astype(np.float32)
    slot_pos = rolling_slot_pos(W, t)        # slot -> absolute position
    got = decode_attention(q1, kc, vc, window=W, kv_len=t, slot_pos=slot_pos,
                           backend="jnp")
    want = decode_ref(q1, kc, vc, window=W, kv_len=t, slot_pos=slot_pos)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    print(f"dynamic input tiles: rotated-cache decode OK "
          f"(wrap at {W}, step {t})")

    # 9. MULTI-GRANULARITY outputs: one grid, outputs accumulated at
    #    DIFFERENT levels of the sequential loop nest — Tile(reduce=<subset
    #    of reduce_axes>). The fused LM head is the showcase: over grid
    #    (rows, nv, nk) with reduce_axes=(1, 2) (vocab blocks outer-
    #    sequential, d blocks inner) its outputs declare THREE granularities
    #    across the op family:
    #      logits  Tile(reduce=(2,))    one block per (row, vocab) cell,
    #                                   accumulated over the d sweep only
    #      m/arg/  Tile(reduce=(1, 2))  one block per row, accumulated over
    #      lse/gold                     BOTH sweeps (online softmax in
    #                                   scratch — running max, rescaled
    #                                   sum-of-exp, gold-token gather)
    #      dx/dw   Tile(reduce=(1,)) /  the backward's transposed pairing
    #              Tile(reduce=(0,))    (dx over vocab blocks, dw over row
    #                                   blocks, ONE grid — like flash bwd)
    #    So logsumexp + the gold logit stream out of ONE matmul pass — the
    #    (rows, vocab) logits never materialize in the CE path — and the
    #    decode path gets the greedy argmax with its logits for free.
    from repro.kernels.lm_head import lm_head_ce, lm_head_ce_ref, lm_head_logits

    R, dm, V, vocab = 16, 32, 96, 70         # padded vocab: 26 masked columns
    xh = rng.randn(R, dm).astype(np.float32)
    wh = rng.randn(dm, V).astype(np.float32)
    labels = rng.randint(0, vocab, (R, 1)).astype(np.int32)
    nll_want = lm_head_ce_ref(xh, wh, labels, vocab=vocab)
    for backend in BACKENDS:
        nll = lm_head_ce(xh, wh, labels, vocab=vocab, block_r=8, block_v=16,
                         block_k=16, backend=backend)
        np.testing.assert_allclose(np.asarray(nll), np.asarray(nll_want),
                                   rtol=1e-4, atol=1e-4)
    # differentiable: the backward recomputes softmax - onehot blockwise
    # from the saved row stats (no logits residual), on the same backend
    dxh = jax.grad(lambda x_: lm_head_ce(
        x_, wh, labels, vocab=vocab, block_r=8, block_v=16, block_k=16,
        backend="jnp").sum())(xh)
    # decode flavor: logits + row max + greedy argmax from the SAME pass
    logits, m, arg = lm_head_logits.raw(xh, wh, vocab=vocab, block_r=8,
                                        block_v=16, block_k=16, backend="jnp")
    assert (np.asarray(arg)[:, 0] ==
            np.asarray(logits)[:, :vocab].argmax(-1)).all()
    print(f"multi-granularity lm_head: fused CE + greedy decode OK "
          f"(|dx| = {float(jnp.abs(dxh).mean()):.3f})")

    # 10. WHAT THE ANALYZER CATCHES: every build runs a static analyzer over
    #     the spec (grid invariants) and an abstract trace of the body (every
    #     ref read/write with its guard context) — bad specs are rejected at
    #     BUILD time with a stable finding code instead of silently computing
    #     different answers per backend. One worked bad spec per code:
    from repro.core import AnalysisError, Device, Scratch

    def race(D):                  # two grid cells write the SAME output block
        def body(ctx, x, y):
            y[...] = x[...]
        return Spec("race", grid=(4,),
                    inputs=[Tile("x", (16,), jnp.float32, block=(4,))],
                    outputs=[Tile("y", (16,), jnp.float32, block=(4,),
                                  index=lambda i: (i // 2,))],
                    body=body)

    def holes(D):                 # half the output blocks are never visited
        def body(ctx, x, y):
            y[...] = x[...]
        return Spec("holes", grid=(2,),
                    inputs=[Tile("x", (16,), jnp.float32, block=(4,),
                                 index=lambda i: (i,))],
                    outputs=[Tile("y", (16,), jnp.float32, block=(4,),
                                  index=lambda i: (i,))],
                    body=body)

    def noinit(D):                # += into scratch with no is_first init:
        def body(ctx, x, out):    # reads undefined VMEM on a real TPU
            acc, = ctx.scratch
            acc[...] += jnp.sum(x[...], keepdims=True)

            @ctx.when(ctx.is_last)
            def _flush():
                out[...] = acc[...]
        return Spec("noinit", grid=(4,), reduce_axes=(0,),
                    scratch=[Scratch((1,), jnp.float32)],
                    inputs=[Tile("x", (16,), jnp.float32, block=(4,),
                                 index=lambda r: (r,))],
                    outputs=[Tile("out", (1,), jnp.float32, block=(1,),
                                  index=lambda r: (0,))],
                    body=body)

    def skippy(D):                # output written ONLY under a skippable
        def body(ctx, x, y):      # guard: skipped blocks keep garbage
            @ctx.cell_when(ctx.outer_id(0) % 2 == 0)
            def _maybe():
                y[...] = x[...] * 2.0
        return Spec("skippy", grid=(4,),
                    inputs=[Tile("x", (16,), jnp.float32, block=(4,))],
                    outputs=[Tile("y", (16,), jnp.float32, block=(4,))],
                    body=body)

    def badsem(D):                # axis declared "parallel" while scratch
        spec = noinit(D)          # carries the accumulation along it
        def body(ctx, x, out):
            acc, = ctx.scratch

            @ctx.when(ctx.is_first)
            def _init():
                acc[...] = jnp.zeros(acc.shape, acc.dtype)
            acc[...] += jnp.sum(x[...], keepdims=True)

            @ctx.when(ctx.is_last)
            def _flush():
                out[...] = acc[...]
        return Spec("badsem", grid=spec.grid, reduce_axes=(0,),
                    dimension_semantics=("parallel",), scratch=spec.scratch,
                    inputs=spec.inputs, outputs=spec.outputs, body=body)

    dev = Device("jnp")
    for bad in (race, holes, noinit, skippy, badsem):
        try:
            dev.build_kernel(bad, {}, analyze="strict")
        except AnalysisError as e:
            print(f"analyzer rejects {bad.__name__!r}: [{e.findings[0].code}]")
        else:
            raise AssertionError(f"{bad.__name__} should have been rejected")
    # the same checks sweep the whole registry: python -m repro.lint_kernels

    # 11. STATIC COST MODEL: the same spec is priced before it ever runs —
    #     per-cell VMEM footprint against the $REPRO_VMEM_BUDGET budget
    #     (EVERY build enforces it: an overflowing spec is a build error,
    #     on all three backends), HBM bytes from one walk of the concrete
    #     grid (consecutive repeats of a block index fetch once, like the
    #     pallas pipeline), and FLOPs from the abstract body trace. Autotune
    #     runs it first and PRUNES candidates that overflow VMEM or are
    #     dominated (>= bytes AND >= flops) before building or timing them.
    from types import SimpleNamespace

    from repro.core import estimate_cost, prune_candidates
    from repro.kernels.matmul import matmul_builder

    D = dict(M=64, K=64, N=64, bm=32, bk=32, bn=32, dtype="float32")
    rep = estimate_cost(matmul_builder(SimpleNamespace(**D)),
                        SimpleNamespace(**D))
    print(f"matmul 64^3 @ 32^3 blocks: vmem {rep.vmem_bytes} B "
          f"({rep.vmem_frac:.1%} of budget), hbm {rep.hbm_bytes} B, "
          f"{rep.flops} flops, {rep.intensity:.2f} flop/B")
    kept, pruned = prune_candidates(
        matmul_builder, D, dict(bm=[32, 64], bn=[32, 64], bk=[32, 64]))
    print(f"sweep 2x2x2: {len(kept)} kept, {len(pruned)} pruned statically "
          "— autotune never builds them (registry-wide: "
          "python -m repro.lint_kernels --cost)")

    # 12. HALO input tiles: stencil kernels declare the fringe they read —
    #     Tile(block=(bh, bw), halo=(r, r), wrap=True) hands the body the
    #     (bh+2r, bw+2r) window around its block, with periodic (wrap=True)
    #     or clamped edges. That is the paper's manual "shared memory"
    #     caching pattern as a declaration: the fd2d leapfrog kernel is the
    #     worked example (repro.apps.fd2d.fd2d_builder), registered as the
    #     tunable `fd2d` op. The analyzer bounds-checks the WIDENED window
    #     (BOUNDS_HALO on overrun), and the cost model charges the halo-
    #     amplified traffic — compare the same 32x32 field before/after:
    #       no halo: each of the 16 cells must fetch the whole 4096 B field
    #                to see its neighbours -> 16 * 4096 B = 65536 B of u1
    #       halo:    each cell fetches only its 10x10 window -> 16 * 400 B
    #                = 6400 B of u1, a 10x cut the model prices statically
    from repro.kernels.apps import fd2d as fd2d_op

    u1 = rng.randn(32, 32).astype(np.float32)
    u2 = rng.randn(32, 32).astype(np.float32)
    want_u3 = fd2d_op.reference(u1, u2)
    for backend in BACKENDS:       # bit-identical periodic edges, 3 backends
        got_u3 = np.asarray(fd2d_op(u1, u2, bh=8, bw=8, backend=backend))
        np.testing.assert_allclose(got_u3, np.asarray(want_u3),
                                   rtol=1e-5, atol=1e-5)
    from repro.apps.fd2d import fd2d_builder

    Dh = SimpleNamespace(**fd2d_op.derive_defines(
        (u1, u2), dict(fd2d_op.defaults, bh=8, bw=8)))
    hrep = estimate_cost(fd2d_builder(Dh), Dh)
    print(f"halo fd2d 32x32 @ 8x8 r=1: u1 window {hrep.vmem_detail['u1']} B "
          f"in VMEM per cell (not the 4096 B field), "
          f"hbm in {hrep.bytes_in} B vs 69632 B whole-field")

    # 13. SHARD-AWARE specs: a grid axis can live ACROSS DEVICES. The spec
    #     declares it — ShardAxis binds one sequential (reduce) axis to a
    #     named mesh axis with its collective (`ppermute` ring rotating the
    #     named input tiles, `psum`/`psum_scatter` for plain reductions) —
    #     and every layer of the front-end picks the declaration up:
    #       analyzer    reasons over the MESH-EXTENDED grid: an accumulating
    #                   output with no collective is COLLECTIVE_UNDECLARED,
    #                   a slot-axis output not declared shard-resident is
    #                   RACE_MESH_WRITE — rejected at build time;
    #       cost model  prices the interconnect: (extent-1) x local bytes
    #                   per rotated tile per shard (the comm column of
    #                   `lint_kernels --cost`);
    #       op call     `op(..., mesh=mesh)` wraps the kernel in shard_map
    #                   per the declared OpShard schedule — and jax
    #                   transposes the ring for the backward (ppermute
    #                   cotangents ride home);
    #       tuning      `tune_cli --arch ... --mesh N` pre-tunes the
    #                   PER-SHARD shapes, winners keyed on the shard extent.
    #     Ring flash attention is the worked example: kv chunks rotate
    #     around the "model" axis as an outer reduce axis. The same per-step
    #     kernel + exact merge also runs WITHOUT a mesh (ring_steps= splits
    #     kv locally) — bit-comparable to the distributed run, which is how
    #     CPU CI proves the schedule (scripts/ci.sh mesh leg: XLA_FLAGS=
    #     --xla_force_host_platform_device_count=8).
    import dataclasses

    from repro.core.lang import defines_namespace
    from repro.kernels.flash_attention import flash_attention, \
        ring_flash, ring_flash_attention
    from repro.kernels.flash_attention.kernel import ring_flash_fwd_builder

    qr = rng.randn(1, 4, 64, 32).astype(np.float32)   # GQA: 4 q / 2 kv heads
    kr = rng.randn(1, 2, 64, 32).astype(np.float32)
    vr = rng.randn(1, 2, 64, 32).astype(np.float32)
    ring_kw = dict(causal=True, block_q=32, block_kv=32, backend="jnp")
    o_ring = ring_flash_attention(qr, kr, vr, ring_steps=4, **ring_kw)
    o_ref = flash_attention(qr, kr, vr, **ring_kw)
    np.testing.assert_allclose(np.asarray(o_ring), np.asarray(o_ref),
                               rtol=1e-4, atol=1e-4)

    _, _, rp = ring_flash._resolve(dict(ring_kw, ring_steps=4))
    _, rdef, _ = ring_flash._prepare((qr[:, :, :16], kr[:, :, :16],
                                      vr[:, :, :16]), rp)
    rD = defines_namespace(rdef)
    rspec = ring_flash_fwd_builder(rD)    # carries the ShardAxis declaration
    rrep = estimate_cost(rspec, rD)
    print(f"ring flash: 4-shard ring over axis {rspec.shard.axis} "
          f"(rotates {rspec.shard.rotate}), comm {rrep.comm_bytes} B/shard, "
          "local == single-device flash")
    try:                                  # drop the rotation: no data ever
        dataclasses.replace(rspec, shard=dataclasses.replace(
            rspec.shard, rotate=()))      # crosses shards -> rejected
    except AnalysisError as e:
        print(f"analyzer rejects the unrotated ring: [{e.findings[0].code}]")

    # 14. SERVE IT — paged KV caches + the continuous-batching engine.
    #     `flash_decode_paged` is flash decode with a BLOCK-TABLE dynamic
    #     input tile: Tile(..., index_tile=("block_table", 0)) makes the kv
    #     index map READ a per-slot i32 page id at run time, so the cache
    #     lives in a pool of fixed-size pages in ANY order (the vLLM
    #     PagedAttention layout) and one compiled kernel serves every slot's
    #     scattered pages. Same declare -> lint -> price pipeline as every
    #     other op: the analyzer bounds-checks the table read (BOUNDS_TABLE
    #     when a page id can overrun the pool) and the cost model prices the
    #     gather per visited page.
    from repro.kernels.flash_attention import (paged_decode_attention,
                                               paged_decode_ref)
    from repro.lint_kernels import cost_op

    page, nsp = 8, 3                          # 3 pages of 8 slots, shuffled
    tab = (np.arange(nsp, dtype=np.int32)[::-1] + 1)[None]  # page 0 = null
    kpool = rng.randn(nsp + 1, 2, page, 32).astype(np.float32)
    vpool = rng.randn(nsp + 1, 2, page, 32).astype(np.float32)
    kvlen = np.array([2 * page + 3], np.int32)       # valid length mid-page
    want_p = paged_decode_ref(q1, kpool, vpool, block_table=tab, kv_len=kvlen)
    for backend in BACKENDS:
        got_p = paged_decode_attention(q1, kpool, vpool, block_table=tab,
                                       kv_len=kvlen, backend=backend)
        np.testing.assert_allclose(np.asarray(got_p), np.asarray(want_p),
                                   rtol=1e-5, atol=1e-6)
    pc = cost_op(registered_ops()["flash_decode_paged"],
                 np.random.RandomState(0))["kernels"][0]
    print(f"paged decode: block-table gather OK on every backend, priced "
          f"vmem {pc['vmem_bytes']} B / hbm {pc['hbm_bytes']} B")

    #     The serving engine drives that kernel: repro.serving.Engine keeps
    #     ONE jitted one-token step running over `batch` slots — per-slot
    #     positions, EOS/max_new retirement with mid-flight slot refill from
    #     the queue, preemption-by-eviction when the page pool runs dry —
    #     and emits bit-identical tokens to per-sequence static decoding
    #     (tests/test_serving.py proves it). `repro.launch.serve.generate`
    #     is now a thin wrapper over it.
    from repro.configs import get_config, reduced
    from repro.models import LM
    from repro.serving import Engine

    cfg = reduced(get_config("llama3_2_1b"))
    lm = LM(cfg)                              # fused_head=True is the default
    eng = Engine(lm, lm.init(jax.random.PRNGKey(0)), batch=2, max_len=32,
                 page_size=8)
    rids = [eng.submit(rng.randint(1, cfg.vocab_size, (n,)).tolist(), m)
            for n, m in ((5, 6), (9, 4), (3, 8))]  # 3 requests, 2 slots
    done = eng.drain()
    print("engine: 3 mixed-length requests through 2 slots ->",
          [len(done[r]) for r in rids], "tokens (slot refill mid-flight)")

    print("one declaration -> every backend, tuned, differentiable, "
          "statically verified, identical results — on one device or a mesh, "
          "up through a continuous-batching serving engine")


if __name__ == "__main__":
    main()
