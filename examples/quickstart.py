"""Quickstart: one kernel source, three backends (the paper's core claim).

  PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core import BACKENDS, Device, Spec, Tile


# 1. Write the kernel ONCE (OCCA-style: grid of work-groups over tiles).
def axpby_builder(D):
    def body(ctx, x, y, out):
        # ctx.outer_id / ctx.lane_ids are the occaOuterId/occaInnerId analogues
        out[...] = D.alpha * x[...] + D.beta * y[...]

    return Spec(
        "axpby", grid=(D.n // D.bn,),
        inputs=[Tile("x", (D.n,), jnp.float32, block=(D.bn,)),
                Tile("y", (D.n,), jnp.float32, block=(D.bn,))],
        outputs=[Tile("out", (D.n,), jnp.float32, block=(D.bn,))],
        body=body)


def main():
    rng = np.random.RandomState(0)
    x = rng.randn(1 << 16).astype(np.float32)
    y = rng.randn(1 << 16).astype(np.float32)

    results = {}
    for backend in BACKENDS:             # "jnp", "loops", "pallas"
        # 2. Pick the backend at RUN TIME (occa::device + addDefine + build).
        device = Device(backend)
        kernel = device.build_kernel(axpby_builder,
                                     dict(n=x.size, bn=4096, alpha=2.0, beta=-0.5))
        o_x, o_y = device.malloc(x), device.malloc(y)
        o_out = device.malloc(np.zeros_like(x))
        # 3. Same call site for every backend (paper listing 9).
        kernel(o_x, o_y, o_out)
        results[backend] = o_out.to_host()
        # runtime compilation cache: second build is a cache hit
        again = device.build_kernel(axpby_builder,
                                    dict(n=x.size, bn=4096, alpha=2.0, beta=-0.5))
        assert again is kernel and device.stats.cache_hits == 1

    want = 2.0 * x - 0.5 * y
    for backend, got in results.items():
        np.testing.assert_allclose(got, want, rtol=1e-6)
        print(f"{backend:>7s}: OK  (max|err| = {np.abs(got - want).max():.2e})")
    print("one kernel source -> three backend expansions, identical results")


if __name__ == "__main__":
    main()
