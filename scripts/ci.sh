#!/usr/bin/env bash
# Staged CI pipeline. Stages (in order):
#
#   deps     install dev deps (best effort — offline machines fall back to
#            tests/_hypothesis_compat.py), verify pytest is importable
#   guards   kernel-library purity: no bespoke pallas_call under
#            src/repro/kernels/ (word-boundary — aliasing `from ... import
#            pallas_call` counts too) and no jax.experimental.pallas import
#            outside src/repro/core/
#   analyze  the kernel static analyzer (python -m repro.lint_kernels
#            --strict --cost) over every registered op + its autotune sweep,
#            including the static cost model (VMEM budget, bytes/FLOPs);
#            findings land as JSON in artifacts/analyze.json and the cost
#            table in artifacts/cost.json
#   tests    the tier-1 suite (extra args after the stage selector are
#            forwarded to pytest)
#   matrix   backend matrix: the cross-backend agreement suites re-run under
#            REPRO_BACKEND=jnp and REPRO_BACKEND=loops, so a regression in a
#            non-default expansion can't hide behind "auto" = pallas
#   mesh     shard-aware language: the mesh/ring suite re-run with XLA
#            forced to 8 host devices (the in-process mesh8 fixtures stop
#            skipping and exercise the real shard_map ring), plus the strict
#            analyzer over the mesh-bound ring specs
#   bench    benchmark smoke (tiny shapes, one rep) writing
#            artifacts/bench_smoke.json, then the row-manifest check — a
#            benchmark row disappearing fails the build — and the perf gate
#            (benchmarks/perf_gate.py): each app's best unified backend must
#            be within 1.5x of its native baseline, and paged decode (the
#            serving engine's block-table path) within 1.3x of contiguous
#
# Usage:
#   scripts/ci.sh                     # all stages
#   scripts/ci.sh --stage guards      # one stage
#   scripts/ci.sh --stage tests -k lm_head   # stage + pytest args
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

STAGES="deps guards analyze tests matrix mesh bench"
if [[ "${1:-}" == "--stage" ]]; then
    [[ $# -ge 2 ]] || { echo "ci.sh: --stage needs a name (one of: $STAGES)" >&2; exit 2; }
    STAGES="$2"
    shift 2
fi

# The cross-backend agreement suites the matrix stage re-runs per backend.
MATRIX_SUITES="tests/test_reduction_lang.py tests/test_define_op.py tests/test_lm_head.py"

stage_deps() {
    if ! python -c "import hypothesis, pytest" >/dev/null 2>&1; then
        python -m pip install -e '.[dev]' \
            || echo "ci.sh: pip install failed (offline?); running with the" \
                    "_hypothesis_compat fixed-example fallback"
    fi
    if ! python -c "import pytest" >/dev/null 2>&1; then
        echo "ci.sh: pytest is not installed and could not be installed" >&2
        echo "ci.sh: the _hypothesis_compat fallback only covers hypothesis" >&2
        return 1
    fi
}

stage_guards() {
    # The unified kernel language is the ONLY way to write a kernel. Word
    # boundary: catches `pl.pallas_call`, bare `pallas_call` and import
    # aliasing (`from jax.experimental.pallas import pallas_call as pc`).
    if grep -rnE '\bpallas_call\b' src/repro/kernels/; then
        echo "ci.sh: bespoke pallas_call found in src/repro/kernels/ —" \
             "port it to the unified language (repro.core.lang)" >&2
        return 1
    fi
    # Backend expansion is core/'s job: nothing outside src/repro/core/ may
    # touch jax.experimental.pallas (kernels would fork per backend again).
    if grep -rn 'jax\.experimental\.pallas' src/repro --include='*.py' \
            | grep -v '^src/repro/core/'; then
        echo "ci.sh: jax.experimental.pallas imported outside" \
             "src/repro/core/ — only the core expansions may touch pallas" >&2
        return 1
    fi
    echo "ci.sh: kernel purity OK"
}

stage_analyze() {
    mkdir -p artifacts
    # --cost folds the static cost model into the strict verdict: a default
    # config tripping VMEM_OVERFLOW (or any other finding) fails the stage.
    python -m repro.lint_kernels --strict --cost \
        --json artifacts/analyze.json --cost-json artifacts/cost.json
}

stage_tests() {
    python -m pytest -x -q "$@"
}

stage_matrix() {
    local be
    for be in jnp loops; do
        echo "ci.sh: backend matrix — REPRO_BACKEND=$be"
        REPRO_BACKEND=$be python -m pytest -q $MATRIX_SUITES
    done
}

stage_mesh() {
    # 8 simulated host devices (must be set before jax imports, hence a
    # fresh pytest process): the mesh8 in-process tests run for real here
    # and the subprocess parity tests re-run under the same forced count.
    XLA_FLAGS="--xla_force_host_platform_device_count=8" \
        python -m pytest -x -q tests/test_mesh_shard.py
    # the shard-aware specs (declared collectives, comm costs) stay clean
    # under the strict analyzer
    python -m repro.lint_kernels --strict --cost --op ring_flash
}

stage_bench() {
    mkdir -p artifacts
    python -m benchmarks.run --smoke --out artifacts/bench_smoke.json \
        --check-manifest benchmarks/smoke_manifest.txt >/dev/null
    # perf gate: best unified backend within 1.5x of the native baseline for
    # every app workload (fd2d / sem / dg volume / dg surface) — the paper's
    # "portability without a performance tax" claim — plus paged decode
    # within 1.3x of contiguous on the served backend, enforced per commit
    python -m benchmarks.perf_gate artifacts/bench_smoke.json
}

for stage in $STAGES; do
    case "$stage" in
        deps|guards|analyze|tests|matrix|mesh|bench) ;;
        *) echo "ci.sh: unknown stage '$stage' (one of: deps guards analyze tests matrix mesh bench)" >&2
           exit 2 ;;
    esac
    echo "ci.sh: stage $stage ..."
    t0=$SECONDS
    if [[ "$stage" == "tests" ]]; then
        "stage_$stage" "$@"
    else
        "stage_$stage"
    fi
    echo "ci.sh: stage $stage OK ($((SECONDS - t0))s)"
done
echo "ci.sh: all stages OK ($STAGES)"
