#!/usr/bin/env bash
# Tier-1 verify: install dev deps (best effort — offline machines fall back
# to tests/_hypothesis_compat.py) and run the canonical test command.
set -euo pipefail
cd "$(dirname "$0")/.."

if ! python -c "import hypothesis, pytest" >/dev/null 2>&1; then
    python -m pip install -e '.[dev]' \
        || echo "ci.sh: pip install failed (offline?); running with the" \
                "_hypothesis_compat fixed-example fallback"
fi

if ! python -c "import pytest" >/dev/null 2>&1; then
    echo "ci.sh: pytest is not installed and could not be installed" >&2
    echo "ci.sh: the _hypothesis_compat fallback only covers hypothesis" >&2
    exit 1
fi

# Purity guard: the unified kernel language is the ONLY way to write a
# kernel — any bespoke pl.pallas_call in the kernel library fails CI.
if grep -rn "pl.pallas_call" src/repro/kernels/; then
    echo "ci.sh: bespoke pl.pallas_call found in src/repro/kernels/ —" \
         "port it to the unified language (repro.core.lang)" >&2
    exit 1
fi
echo "ci.sh: kernel purity OK (no pl.pallas_call under src/repro/kernels/)"

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"

# Benchmark smoke: tiny shapes, one rep — every benchmark path must still
# build and run, so benchmark drift breaks tier-1 instead of rotting silently.
echo "ci.sh: benchmark smoke run"
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.run --smoke >/dev/null
echo "ci.sh: benchmark smoke OK"
